"""GGADMM / C-GGADMM / CQ-GGADMM — the paper's Algorithms 1 and 2.

One unified stepper covers the whole family (and the Jacobian C-ADMM
baseline in ``admm_baselines``) through three orthogonal switches:

  * alternating head/tail groups (GADMM-style)  vs  Jacobian (all-parallel),
  * censoring  (tau0 > 0),
  * stochastic quantization  (quantize=True).

Per-iteration structure of CQ-GGADMM (Algorithm 2), fully vectorized over a
leading worker axis, with group selection done by masks so the same traced
program serves any bipartite graph:

  phase 1 (heads):  theta_H <- argmin f + <theta, alpha - rho * A theta_hat> + rho d/2 ||theta||^2
                    quantize -> Q_hat, censor -> theta_hat_H
  phase 2 (tails):  same, but neighbors see the *fresh* head theta_hat
  dual:             alpha += rho * (D - A) theta_hat        (Eq. 23)

The stepper is scanned with ``jax.lax.scan``; all communication metrics
(transmission masks, exact payload bits) are emitted per iteration so the
benchmark harness can reproduce the paper's Figs. 2-6 axes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Protocol, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.censoring import CensorConfig, apply_censoring, censor_mask
from repro.core.graph import WorkerGraph
from repro.core.quantization import (QuantConfig, QuantizerState,
                                     identity_quantize_step, quantize_step)


class PrimalSolver(Protocol):
    def primal_solve(self, v: jax.Array, rho_d: jax.Array,
                     theta_init: Optional[jax.Array] = None) -> jax.Array:
        ...


@dataclasses.dataclass(frozen=True)
class ADMMConfig:
    rho: float = 1.0
    alternating: bool = True          # GADMM grouping; False => Jacobian ADMM
    censor: CensorConfig = dataclasses.field(default_factory=CensorConfig)
    quantize: Optional[QuantConfig] = None
    use_pallas_mix: bool = False      # route A @ theta_hat through the kernel
    use_pallas_quant: bool = False

    @property
    def name(self) -> str:
        if not self.alternating:
            return "c-admm" if self.censor.enabled else "jacobian-admm"
        tag = "ggadmm"
        if self.censor.enabled:
            tag = "c-" + tag
        if self.quantize is not None:
            tag = ("cq-" + tag[2:]) if tag.startswith("c-") else "q-" + tag
        return tag


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ADMMState:
    theta: jax.Array        # (N, d) primal variables theta_n^k
    theta_hat: jax.Array    # (N, d) last *transmitted* value (theta-tilde / theta-hat)
    alpha: jax.Array        # (N, d) duals alpha_n^k = sum_m lambda_{n,m}
    quant: QuantizerState   # quantizer replicas (inert when quantize=None)
    k: jax.Array            # iteration counter


def init_state(n_workers: int, dim: int, cfg: ADMMConfig,
               dtype=jnp.float32) -> ADMMState:
    qcfg = cfg.quantize or QuantConfig()
    return ADMMState(
        theta=jnp.zeros((n_workers, dim), dtype),
        theta_hat=jnp.zeros((n_workers, dim), dtype),
        alpha=jnp.zeros((n_workers, dim), dtype),   # alpha^0 = 0 in col(M_-)
        quant=QuantizerState.create(n_workers, dim, b0=qcfg.b0, dtype=dtype),
        k=jnp.zeros((), jnp.int32),
    )


def _neighbor_sum(adjacency: jax.Array, theta_hat: jax.Array,
                  use_kernel: bool) -> jax.Array:
    """sum_{m in N_n} theta_hat_m  =  A @ theta_hat."""
    if use_kernel:
        from repro.kernels import ops as kernel_ops
        return kernel_ops.bipartite_mix(adjacency, theta_hat)
    return adjacency @ theta_hat


def _phase(state: ADMMState, group_mask: jax.Array, solver: PrimalSolver,
           adjacency: jax.Array, rho_d: jax.Array, cfg: ADMMConfig,
           key: jax.Array) -> Tuple[ADMMState, jax.Array, jax.Array]:
    """One group's primal update + (quantize) + (censor) + commit.

    Returns (new_state, tx_mask, payload_bits) restricted to `group_mask`
    (zeros elsewhere).
    """
    rho = cfg.rho
    neigh = _neighbor_sum(adjacency, state.theta_hat, cfg.use_pallas_mix)
    if cfg.alternating:
        # GGADMM primal, Eqs. (11)/(12)/(21)/(22):
        #   min f + <theta, alpha - rho * A theta_hat> + rho d/2 ||theta||^2
        v = state.alpha - rho * neigh
        quad = rho_d
    else:
        # Jacobian C-ADMM primal (Liu et al., 2019b): proximal self-anchoring
        #   min f + <theta, alpha> + rho sum_j ||theta - (th_i + th_j)/2||^2
        # => quadratic coeff 2 rho d_i, linear alpha - rho (d_i th_i + A th).
        v = state.alpha - rho_d[:, None] * state.theta_hat - rho * neigh
        quad = 2.0 * rho_d
    theta_new_full = solver.primal_solve(v, quad, theta_init=state.theta)
    gm = group_mask[:, None]
    theta = jnp.where(gm > 0, theta_new_full, state.theta)

    if cfg.quantize is not None:
        quant_new, candidate, _, payload = quantize_step(
            state.quant, theta, key, cfg.quantize,
            use_kernel=cfg.use_pallas_quant)
    else:
        quant_new, candidate, _, payload = identity_quantize_step(
            state.quant, theta, key, QuantConfig())

    k_next = state.k + 1
    cmask = censor_mask(state.theta_hat, candidate, cfg.censor,
                        k_next.astype(jnp.float32))
    tx_mask = cmask * group_mask                        # only this group acts
    theta_hat = apply_censoring(state.theta_hat, candidate, tx_mask)

    # Commit quantizer state only for this group's workers (they are the ones
    # that ran Eq. (20) this phase).
    def commit(new, old):
        if new.ndim == old.ndim == 2:
            return jnp.where(gm > 0, new, old)
        return jnp.where(group_mask > 0, new, old)

    quant = jax.tree_util.tree_map(commit, quant_new, state.quant)
    new_state = dataclasses.replace(state, theta=theta, theta_hat=theta_hat,
                                    quant=quant)
    return new_state, tx_mask, payload * group_mask


def make_step(graph: WorkerGraph, solver: PrimalSolver, cfg: ADMMConfig):
    """Build the jittable per-iteration step function.

    step(state, key) -> (state, metrics) where metrics carries per-worker
    transmission masks and payload bits plus residual diagnostics.
    """
    adjacency = jnp.asarray(graph.adjacency)
    degrees = jnp.asarray(graph.degrees)
    head = jnp.asarray(graph.head_mask, jnp.float32)
    tail = 1.0 - head
    rho_d = cfg.rho * degrees

    def step(state: ADMMState, key: jax.Array):
        k1, k2 = jax.random.split(key)
        if cfg.alternating:
            state, tx_h, pay_h = _phase(state, head, solver, adjacency,
                                        rho_d, cfg, k1)
            state, tx_t, pay_t = _phase(state, tail, solver, adjacency,
                                        rho_d, cfg, k2)
            tx_mask = tx_h + tx_t
            payload = pay_h + pay_t
        else:
            all_mask = jnp.ones_like(head)
            state, tx_mask, payload = _phase(state, all_mask, solver,
                                             adjacency, rho_d, cfg, k1)

        # Dual update, Eq. (23): alpha += rho * (D - A) theta_hat.
        lap = degrees[:, None] * state.theta_hat - adjacency @ state.theta_hat
        alpha = state.alpha + cfg.rho * lap
        state = dataclasses.replace(state, alpha=alpha, k=state.k + 1)

        # Residual diagnostics (Eq. 28): sum over edges ||theta_n - theta_m||^2.
        diffs = state.theta[:, None, :] - state.theta[None, :, :]
        primal_res = jnp.sum(adjacency * jnp.sum(diffs ** 2, axis=-1)) / 2.0
        metrics = {
            "tx_mask": tx_mask,
            "payload_bits": payload,
            "primal_residual": primal_res,
            "theta": state.theta,
        }
        return state, metrics

    return step


def run(graph: WorkerGraph, solver: PrimalSolver, cfg: ADMMConfig,
        dim: int, iters: int, seed: int = 0,
        theta_star: Optional[jax.Array] = None,
        local_loss=None) -> Tuple[ADMMState, Dict[str, Any]]:
    """Scan the stepper for `iters` iterations and stack metrics.

    If `local_loss` (callable (N,d)->(N,)) and/or `theta_star` are given,
    objective-gap and distance-to-optimum trajectories are included.
    """
    state = init_state(graph.n, dim, cfg)
    step = make_step(graph, solver, cfg)
    keys = jax.random.split(jax.random.PRNGKey(seed), iters)

    def body(carry, key):
        new_state, m = step(carry, key)
        return new_state, m

    final_state, metrics = jax.lax.scan(body, state, keys)
    out: Dict[str, Any] = {
        "tx_mask": metrics["tx_mask"],
        "payload_bits": metrics["payload_bits"],
        "primal_residual": metrics["primal_residual"],
    }
    thetas = metrics["theta"]                      # (K, N, d)
    if local_loss is not None:
        out["objective"] = jax.vmap(lambda th: jnp.sum(local_loss(th)))(thetas)
    if theta_star is not None:
        err = thetas - theta_star[None, None, :]
        out["dist_to_opt"] = jnp.sum(err ** 2, axis=(1, 2))
    return final_state, jax.tree_util.tree_map(np.asarray, out)
