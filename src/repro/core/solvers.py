"""Primal subproblem solvers for the (CQ-G)GADMM updates.

Every primal update in the paper (Eqs. 8/9, 11/12, 21/22) has the form

    theta_n^{k+1} = argmin_theta  f_n(theta) + <theta, v_n> + (rho d_n / 2) ||theta||^2
    with   v_n = alpha_n^k - rho * sum_{m in N_n} (received neighbor value).

This module provides batched-over-workers solvers for the paper's two tasks:

  * linear regression  f_n = 0.5 ||X_n theta - y_n||^2          -> closed form
  * logistic regression f_n = (1/s) sum log(1+exp(-y x'theta)) + mu0/2||theta||^2
                                                                -> Newton steps

plus a generic gradient-descent fallback for arbitrary differentiable f_n.
Neural-network (pytree) subproblems are solved inexactly in
``repro.core.consensus`` with Adam steps.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class LinearRegressionProblem:
    """Per-worker least squares: X (N, s, d), y (N, s)."""

    x: jax.Array
    y: jax.Array

    @property
    def n_workers(self) -> int:
        return self.x.shape[0]

    @property
    def dim(self) -> int:
        return self.x.shape[-1]

    def local_loss(self, theta: jax.Array) -> jax.Array:
        """(N,) local objective f_n(theta_n) for stacked theta (N, d)."""
        resid = jnp.einsum("nsd,nd->ns", self.x, theta) - self.y
        return 0.5 * jnp.sum(resid ** 2, axis=-1)

    def global_loss(self, theta_bar: jax.Array) -> jax.Array:
        """Scalar sum_n f_n(theta) at a single shared theta (d,)."""
        resid = jnp.einsum("nsd,d->ns", self.x, theta_bar) - self.y
        return 0.5 * jnp.sum(resid ** 2)

    def optimum(self) -> jax.Array:
        """Closed-form consensus optimum of (P1)."""
        gram = jnp.einsum("nsd,nse->de", self.x, self.x)
        rhs = jnp.einsum("nsd,ns->d", self.x, self.y)
        return jnp.linalg.solve(gram + 1e-9 * jnp.eye(self.dim), rhs)

    def primal_solve(self, v: jax.Array, rho_d: jax.Array,
                     theta_init: Optional[jax.Array] = None) -> jax.Array:
        """argmin over theta of f_n + <theta, v_n> + rho*d_n/2 ||theta||^2.

        Solves (X_n^T X_n + rho d_n I) theta = X_n^T y_n - v_n, batched.
        `theta_init` is ignored (closed form).
        """
        del theta_init
        gram = jnp.einsum("nsd,nse->nde", self.x, self.x)
        eye = jnp.eye(self.dim, dtype=gram.dtype)
        lhs = gram + rho_d[:, None, None] * eye[None]
        rhs = jnp.einsum("nsd,ns->nd", self.x, self.y) - v
        return jnp.linalg.solve(lhs, rhs[..., None])[..., 0]


@dataclasses.dataclass(frozen=True)
class LogisticRegressionProblem:
    """Per-worker binary logistic regression with L2 term mu0/2 ||theta||^2.

    x: (N, s, d), y: (N, s) in {-1, +1}.
    """

    x: jax.Array
    y: jax.Array
    mu0: float = 1e-3
    newton_steps: int = 8

    @property
    def n_workers(self) -> int:
        return self.x.shape[0]

    @property
    def dim(self) -> int:
        return self.x.shape[-1]

    def local_loss(self, theta: jax.Array) -> jax.Array:
        s = self.x.shape[1]
        margins = self.y * jnp.einsum("nsd,nd->ns", self.x, theta)
        nll = jnp.sum(jnp.logaddexp(0.0, -margins), axis=-1) / s
        return nll + 0.5 * self.mu0 * jnp.sum(theta ** 2, axis=-1)

    def global_loss(self, theta_bar: jax.Array) -> jax.Array:
        s = self.x.shape[1]
        margins = self.y * jnp.einsum("nsd,d->ns", self.x, theta_bar)
        nll = jnp.sum(jnp.logaddexp(0.0, -margins), axis=-1) / s
        reg = 0.5 * self.mu0 * jnp.sum(theta_bar ** 2)
        return jnp.sum(nll) + self.n_workers * reg

    def optimum(self, steps: int = 200) -> jax.Array:
        """Newton solve of the *global* problem (for optimality-gap curves)."""
        theta = jnp.zeros((self.dim,), self.x.dtype)

        def body(_, th):
            g = jax.grad(self.global_loss)(th)
            h = jax.hessian(self.global_loss)(th)
            return th - jnp.linalg.solve(h + 1e-9 * jnp.eye(self.dim), g)

        return jax.lax.fori_loop(0, steps, body, theta)

    def primal_solve(self, v: jax.Array, rho_d: jax.Array,
                     theta_init: Optional[jax.Array] = None) -> jax.Array:
        """Batched Newton solve of the augmented local subproblem."""
        s = self.x.shape[1]
        theta0 = theta_init if theta_init is not None else jnp.zeros(
            (self.n_workers, self.dim), self.x.dtype)

        def subproblem_grad_hess(theta):
            margins = self.y * jnp.einsum("nsd,nd->ns", self.x, theta)
            sig = jax.nn.sigmoid(-margins)                       # (N, s)
            grad = (-jnp.einsum("ns,ns,nsd->nd", self.y, sig, self.x) / s
                    + (self.mu0 + rho_d[:, None]) * theta + v)
            w = sig * (1.0 - sig)                                # (N, s)
            hess = jnp.einsum("ns,nsd,nse->nde", w, self.x, self.x) / s
            eye = jnp.eye(self.dim, dtype=theta.dtype)
            hess = hess + (self.mu0 + rho_d)[:, None, None] * eye[None]
            return grad, hess

        def body(_, theta):
            g, h = subproblem_grad_hess(theta)
            return theta - jnp.linalg.solve(h, g[..., None])[..., 0]

        return jax.lax.fori_loop(0, self.newton_steps, body, theta0)


@dataclasses.dataclass(frozen=True)
class GradientDescentSolver:
    """Generic inexact primal solver: K GD steps on the augmented subproblem.

    local_grad(theta) must return the (N, d) batched gradient of f_n.
    """

    local_grad: Callable[[jax.Array], jax.Array]
    steps: int = 20
    lr: float = 0.05

    def primal_solve(self, v: jax.Array, rho_d: jax.Array,
                     theta_init: jax.Array) -> jax.Array:
        def body(_, theta):
            g = self.local_grad(theta) + v + rho_d[:, None] * theta
            return theta - self.lr * g

        return jax.lax.fori_loop(0, self.steps, body, theta_init)
