"""Benchmark schemes of Sec. 7.

* C-ADMM (Liu et al., 2019b): censoring on top of the *Jacobian* decentralized
  ADMM — all workers update and (band-sharing-permitting) transmit in
  parallel every iteration, no worker grouping, no quantization. In the
  unified engine this is ``alternating=False`` + censoring.
* GGADMM / C-GGADMM ablations are ``EngineConfig`` presets (``ADMMConfig``
  is its flat-adapter alias).
* Q-GGADMM (quantization without censoring) is included as an extra ablation
  beyond the paper's plotted set (it is the GGADMM analogue of Q-GADMM).

Every preset runs through ``core/engine.py`` — pass ``groups="leaf"`` /
``censor_mode="group"`` to any of them for the layer-aware modes.
"""
from __future__ import annotations

from repro.core.censoring import CensorConfig
from repro.core.engine import EngineConfig as ADMMConfig
from repro.core.quantization import QuantConfig


def ggadmm(rho: float = 1.0) -> ADMMConfig:
    return ADMMConfig(rho=rho, alternating=True)


def c_ggadmm(rho: float = 1.0, tau0: float = 1.0, xi: float = 0.8) -> ADMMConfig:
    return ADMMConfig(rho=rho, alternating=True,
                      censor=CensorConfig(tau0=tau0, xi=xi))


def cq_ggadmm(rho: float = 1.0, tau0: float = 1.0, xi: float = 0.8,
              b0: int = 2, omega: float = 0.99) -> ADMMConfig:
    return ADMMConfig(rho=rho, alternating=True,
                      censor=CensorConfig(tau0=tau0, xi=xi),
                      quantize=QuantConfig(b0=b0, omega=omega))


def q_ggadmm(rho: float = 1.0, b0: int = 2, omega: float = 0.99) -> ADMMConfig:
    return ADMMConfig(rho=rho, alternating=True,
                      quantize=QuantConfig(b0=b0, omega=omega))


def c_admm(rho: float = 1.0, tau0: float = 1.0, xi: float = 0.8) -> ADMMConfig:
    """Censored Jacobian decentralized ADMM (Liu et al., 2019b)."""
    return ADMMConfig(rho=rho, alternating=False,
                      censor=CensorConfig(tau0=tau0, xi=xi))


def jacobian_admm(rho: float = 1.0) -> ADMMConfig:
    return ADMMConfig(rho=rho, alternating=False)


ALL_SCHEMES = {
    "ggadmm": ggadmm,
    "c-ggadmm": c_ggadmm,
    "cq-ggadmm": cq_ggadmm,
    "q-ggadmm": q_ggadmm,
    "c-admm": c_admm,
    "jacobian-admm": jacobian_admm,
}
