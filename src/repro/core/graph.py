"""Bipartite connected worker graphs for (CQ-G)GADMM.

The paper (Assumption 1) requires the communication graph G to be bipartite
and connected. Workers are split into a head group H and a tail group T; all
edges go between groups. This module builds such graphs, including the random
connectivity-ratio-p graphs of Sec. 7 ("Graph Generation"), and exposes the
matrices used by the convergence analysis (Appendix D): adjacency A,
bi-adjacency B, degree D, signed/unsigned incidence M_-, M_+, and the
asymmetric update matrix C of Eq. (115).

Everything is plain numpy at construction time (graphs are static metadata);
the returned `WorkerGraph` carries jnp-ready arrays for the algorithm.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class WorkerGraph:
    """Static description of a bipartite connected worker graph.

    Attributes:
      n: number of workers (|V|).
      edges: (E, 2) int array; every edge is (head, tail) with head in H,
        tail in T (paper's convention E = {(n, m) | n in H, m in T}).
      head_mask: (n,) bool, True for head workers.
      adjacency: (n, n) float32 symmetric 0/1 matrix A (Eq. 114).
      degrees: (n,) float32 node degrees d_n = |N_n|.

    Beyond the dense matrices, the graph carries precomputed *edge-list /
    CSR* views of the same topology (``edge_src``/``edge_dst``,
    ``csr_offsets``/``csr_indices``, ``neighbor_table``) — the O(E) inputs
    of the sparse mixing backend (``core/topology.py``). They are derived
    lazily from ``edges`` and cached on the instance; ``validate()``
    round-trips them against ``adjacency``.
    """

    n: int
    edges: np.ndarray
    head_mask: np.ndarray
    adjacency: np.ndarray
    degrees: np.ndarray

    # -- edge-list / CSR views (sparse-backend metadata) -------------------
    @functools.cached_property
    def _directed_edges(self) -> Tuple[np.ndarray, np.ndarray]:
        """Both orientations of every undirected edge, sorted by
        (destination, source): ``out[dst] += V[src]`` visits each node's
        incoming contributions contiguously."""
        e = np.asarray(self.edges, dtype=np.int64)
        src = np.concatenate([e[:, 0], e[:, 1]]).astype(np.int32)
        dst = np.concatenate([e[:, 1], e[:, 0]]).astype(np.int32)
        order = np.lexsort((src, dst))
        return src[order], dst[order]

    @property
    def edge_src(self) -> np.ndarray:
        """(2E,) int32 source node of each directed edge (dst-sorted)."""
        return self._directed_edges[0]

    @property
    def edge_dst(self) -> np.ndarray:
        """(2E,) int32 destination node of each directed edge (sorted)."""
        return self._directed_edges[1]

    @functools.cached_property
    def csr_offsets(self) -> np.ndarray:
        """(N+1,) int32 CSR row pointers: node n's neighbors are
        ``csr_indices[csr_offsets[n]:csr_offsets[n + 1]]``."""
        counts = np.bincount(self.edge_dst, minlength=self.n)
        offsets = np.zeros(self.n + 1, dtype=np.int64)
        np.cumsum(counts, out=offsets[1:])
        return offsets.astype(np.int32)

    @property
    def csr_indices(self) -> np.ndarray:
        """(2E,) int32 CSR column indices (= ``edge_src``: dst-sorted
        directed edges ARE the CSR layout)."""
        return self.edge_src

    @property
    def max_degree(self) -> int:
        return int(self.degrees.max())

    @functools.cached_property
    def neighbor_table(self) -> Tuple[np.ndarray, np.ndarray]:
        """Degree-padded CSR: ``(table (N, S) int32, valid (N, S) f32)``
        with S = max_degree; slot s of row n is n's s-th neighbor (pad
        rows point at node 0 with valid = 0). This is the rectangular
        layout the Pallas edge-gather mix kernel consumes."""
        s = max(self.max_degree, 1)
        table = np.zeros((self.n, s), dtype=np.int32)
        valid = np.zeros((self.n, s), dtype=np.float32)
        offsets, indices = self.csr_offsets, self.csr_indices
        for node in range(self.n):
            lo, hi = int(offsets[node]), int(offsets[node + 1])
            table[node, :hi - lo] = indices[lo:hi]
            valid[node, :hi - lo] = 1.0
        return table, valid

    # -- derived matrices (Appendix D) ------------------------------------
    @property
    def tail_mask(self) -> np.ndarray:
        return ~self.head_mask

    @property
    def num_edges(self) -> int:
        return int(self.edges.shape[0])

    @property
    def degree_matrix(self) -> np.ndarray:
        """Diagonal degree matrix D."""
        return np.diag(self.degrees).astype(np.float32)

    @property
    def c_matrix(self) -> np.ndarray:
        """Matrix C of Eq. (115): head->tail half of A (rows=heads' view).

        C[n, m] = A[n, m] if n in H and m in T else 0. With workers ordered
        arbitrarily, this is A masked to (head rows, tail cols).
        """
        c = self.adjacency.copy()
        c[~self.head_mask, :] = 0.0
        c[:, self.head_mask] = 0.0
        return c.astype(np.float32)

    @property
    def signed_incidence(self) -> np.ndarray:
        """Signed incidence matrix M_- of shape (n, E): +1 at head, -1 at tail."""
        m = np.zeros((self.n, self.num_edges), dtype=np.float32)
        for e, (h, t) in enumerate(self.edges):
            m[h, e] = 1.0
            m[t, e] = -1.0
        return m

    @property
    def unsigned_incidence(self) -> np.ndarray:
        """Unsigned incidence matrix M_+ of shape (n, E): +1 at both ends."""
        m = np.zeros((self.n, self.num_edges), dtype=np.float32)
        for e, (h, t) in enumerate(self.edges):
            m[h, e] = 1.0
            m[t, e] = 1.0
        return m

    def validate(self) -> None:
        """Check bipartiteness, connectivity and matrix identities."""
        a = self.adjacency
        assert np.allclose(a, a.T), "adjacency must be symmetric"
        assert a.diagonal().sum() == 0, "no self loops"
        # bipartite: no head-head or tail-tail edges
        hh = a[np.ix_(self.head_mask, self.head_mask)]
        tt = a[np.ix_(self.tail_mask, self.tail_mask)]
        assert hh.sum() == 0 and tt.sum() == 0, "graph not bipartite"
        assert is_connected(a), "graph not connected"
        # Appendix D identities (the paper's factors 1/2 and 1/4 correspond to
        # a doubled, per-orientation edge set; with each undirected edge
        # listed once they read):  D - A = M- M-^T ;  A = 1/2(M+M+^T - M-M-^T)
        m_minus = self.signed_incidence
        m_plus = self.unsigned_incidence
        np.testing.assert_allclose(
            self.degree_matrix - a, m_minus @ m_minus.T, atol=1e-5)
        np.testing.assert_allclose(
            a, 0.5 * (m_plus @ m_plus.T - m_minus @ m_minus.T), atol=1e-5)
        c = self.c_matrix
        np.testing.assert_allclose(a, c + c.T, atol=1e-5)
        # edge-list / CSR views reconstruct the same adjacency
        src, dst = self.edge_src, self.edge_dst
        assert src.shape == dst.shape == (2 * self.num_edges,)
        rebuilt = np.zeros_like(a)
        np.add.at(rebuilt, (dst, src), 1.0)
        np.testing.assert_array_equal(rebuilt, a)
        assert (np.diff(dst) >= 0).all(), "directed edges must be dst-sorted"
        offsets = self.csr_offsets
        np.testing.assert_array_equal(np.diff(offsets),
                                      self.degrees.astype(np.int64))
        table, valid = self.neighbor_table
        np.testing.assert_array_equal(valid.sum(axis=1),
                                      self.degrees.astype(np.float32))
        rebuilt_t = np.zeros_like(a)
        rows = np.repeat(np.arange(self.n), table.shape[1])
        np.add.at(rebuilt_t, (rows, table.ravel()), valid.ravel())
        np.testing.assert_array_equal(rebuilt_t, a)

    def connectivity_ratio(self) -> float:
        """p = |E| / (N(N-1)/2), the paper's density measure."""
        return self.num_edges / (self.n * (self.n - 1) / 2.0)


def is_connected(adjacency: np.ndarray) -> bool:
    n = adjacency.shape[0]
    seen = np.zeros(n, dtype=bool)
    stack = [0]
    seen[0] = True
    while stack:
        u = stack.pop()
        for v in np.nonzero(adjacency[u] > 0)[0]:
            if not seen[v]:
                seen[v] = True
                stack.append(int(v))
    return bool(seen.all())


def _finalize(n: int, edges: Sequence[Tuple[int, int]],
              head_mask: np.ndarray) -> WorkerGraph:
    edges_arr = np.asarray(sorted(set(edges)), dtype=np.int64)
    adj = np.zeros((n, n), dtype=np.float32)
    for h, t in edges_arr:
        adj[h, t] = 1.0
        adj[t, h] = 1.0
    degrees = adj.sum(axis=1).astype(np.float32)
    g = WorkerGraph(n=n, edges=edges_arr, head_mask=head_mask,
                    adjacency=adj, degrees=degrees)
    g.validate()
    return g


def chain_graph(n: int) -> WorkerGraph:
    """The original GADMM chain: worker i connected to i+1; H=even, T=odd."""
    assert n >= 2
    head_mask = (np.arange(n) % 2 == 0)
    edges = []
    for i in range(n - 1):
        h, t = (i, i + 1) if head_mask[i] else (i + 1, i)
        edges.append((h, t))
    return _finalize(n, edges, head_mask)


def complete_bipartite_graph(n_heads: int, n_tails: int) -> WorkerGraph:
    n = n_heads + n_tails
    head_mask = np.zeros(n, dtype=bool)
    head_mask[:n_heads] = True
    edges = [(h, t) for h in range(n_heads) for t in range(n_heads, n)]
    return _finalize(n, edges, head_mask)


def star_graph(n: int) -> WorkerGraph:
    """Worker 0 (head) connected to all others (tails): a 2-coloring of a star."""
    head_mask = np.zeros(n, dtype=bool)
    head_mask[0] = True
    edges = [(0, t) for t in range(1, n)]
    return _finalize(n, edges, head_mask)


def random_bipartite_graph(n: int, p: float, seed: int = 0,
                           n_heads: Optional[int] = None) -> WorkerGraph:
    """Random connected bipartite graph with connectivity ratio ~p (Sec. 7).

    Following Shi et al. (2014) / the paper's generator: target
    round(p * N(N-1)/2) edges chosen uniformly among head-tail pairs, after
    seeding a random spanning structure to guarantee connectivity. Note that
    a bipartite graph caps the achievable ratio at |H||T| / (N(N-1)/2).
    """
    assert n >= 2 and 0.0 < p <= 1.0
    rng = np.random.default_rng(seed)
    if n_heads is None:
        n_heads = n // 2
    assert 1 <= n_heads < n
    perm = rng.permutation(n)
    heads = perm[:n_heads]
    tails = perm[n_heads:]
    head_mask = np.zeros(n, dtype=bool)
    head_mask[heads] = True

    # spanning tree over the bipartite structure: connect alternating sides.
    edges = set()
    connected = [int(heads[0])]
    remaining = [int(x) for x in perm if int(x) != int(heads[0])]
    rng.shuffle(remaining)
    for v in remaining:
        # attach v to a random already-connected node of the opposite side
        opposite = [u for u in connected if head_mask[u] != head_mask[v]]
        if not opposite:
            # must attach through a 2-hop: pick any connected node w of same
            # side, then we cannot add (v, w); instead postpone v.
            remaining.append(v)
            continue
        u = int(rng.choice(opposite))
        h, t = (u, v) if head_mask[u] else (v, u)
        edges.add((int(h), int(t)))
        connected.append(v)

    target = int(round(p * n * (n - 1) / 2.0))
    all_pairs = [(int(h), int(t)) for h in heads for t in tails]
    rng.shuffle(all_pairs)
    for pair in all_pairs:
        if len(edges) >= target:
            break
        edges.add(pair)
    return _finalize(n, sorted(edges), head_mask)


def pod_pair_graph() -> WorkerGraph:
    """The 2-worker graph used for pod-granular consensus: one edge H-T."""
    return complete_bipartite_graph(1, 1)


def membership_graph(n: int, p: float, seed: int = 0,
                     epoch: int = 0) -> WorkerGraph:
    """Redraw the fleet's communication graph for its current membership.

    One membership *epoch* = one (join/leave) event; each epoch gets an
    independent connected bipartite graph over the surviving + joined
    workers, with the head/tail split rebalanced to ``n // 2`` heads (the
    random generator's default) — so a fleet that churns down to N=2 still
    gets the single-edge H-T pair and ``validate()`` keeps holding. The
    draw is a pure function of ``(seed, epoch, n)`` (hashed through
    ``SeedSequence`` so consecutive epochs are decorrelated), which is what
    makes churn traces replayable from one fleet seed.

    All CSR/edge-list metadata (``edge_src``/``edge_dst``,
    ``csr_offsets``/``csr_indices``, ``neighbor_table``,
    ``signed_incidence``) re-derives lazily on the fresh instance — there
    is no stale-cache hazard across membership changes by construction.
    """
    assert n >= 2, f"fleet membership must keep >= 2 workers, got {n}"
    derived = int(np.random.SeedSequence([seed, epoch, n]).generate_state(1)[0])
    return random_bipartite_graph(n, p, seed=derived)
