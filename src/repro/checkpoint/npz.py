"""npz pytree checkpointer (orbax is not available offline).

Layout: <dir>/step_<k>.npz with leaves stored under their jax keystr paths,
plus a tiny JSON sidecar describing the tree for restore-time validation.
``latest_step`` scans the directory; ``restore`` rebuilds into the template
pytree (shape/dtype checked leaf by leaf).
"""
from __future__ import annotations

import json
import re
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

_STEP_RE = re.compile(r"step_(\d+)\.npz$")


def _flatten(tree: Any):
    leaves = jax.tree_util.tree_leaves_with_path(tree)
    return {jax.tree_util.keystr(path): np.asarray(leaf)
            for path, leaf in leaves}


def save(directory: str | Path, step: int, tree: Any,
         keep: Optional[int] = 3) -> Path:
    """Write step_<k>.npz (+ manifest); prune to the newest `keep`."""
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    flat = _flatten(tree)
    path = directory / f"step_{step}.npz"
    tmp = path.with_suffix(".tmp.npz")
    np.savez(tmp, **flat)
    tmp.rename(path)
    manifest = {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                for k, v in flat.items()}
    (directory / f"step_{step}.json").write_text(json.dumps(manifest))
    if keep is not None:
        steps = sorted(all_steps(directory))
        for old in steps[:-keep]:
            (directory / f"step_{old}.npz").unlink(missing_ok=True)
            (directory / f"step_{old}.json").unlink(missing_ok=True)
    return path


def all_steps(directory: str | Path):
    directory = Path(directory)
    if not directory.exists():
        return []
    return [int(m.group(1)) for p in directory.iterdir()
            if (m := _STEP_RE.search(p.name))]


def latest_step(directory: str | Path) -> Optional[int]:
    steps = all_steps(directory)
    return max(steps) if steps else None


def restore(directory: str | Path, template: Any,
            step: Optional[int] = None) -> tuple[Any, int]:
    """Rebuild `template`'s pytree from the newest (or given) checkpoint."""
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    data = np.load(directory / f"step_{step}.npz")
    leaves_with_path = jax.tree_util.tree_leaves_with_path(template)
    treedef = jax.tree_util.tree_structure(template)
    out = []
    for path, leaf in leaves_with_path:
        key = jax.tree_util.keystr(path)
        if key not in data:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = data[key]
        want_shape = tuple(getattr(leaf, "shape", np.shape(leaf)))
        if tuple(arr.shape) != want_shape:
            raise ValueError(
                f"leaf {key}: checkpoint shape {arr.shape} != {want_shape}")
        want_dtype = getattr(leaf, "dtype", None)
        out.append(arr.astype(want_dtype) if want_dtype else arr)
    return jax.tree_util.tree_unflatten(treedef, out), step
