"""npz pytree checkpointer (orbax is not available offline).

Layout: <dir>/step_<k>.npz with leaves stored under their jax keystr paths,
plus a tiny JSON sidecar describing the tree for restore-time validation.
A step is *complete* only when both files exist: the npz is renamed into
place first and the manifest second (each written tmp-then-rename, so a
crash at any point leaves either a previous complete step or a harmless
orphan, never a torn file), and ``all_steps``/``latest_step``/pruning only
consider complete steps — a concurrent ``restore`` can never pick a step
whose manifest (or data) is still missing, and pruning drops the manifest
before the data so a step disappears from listings before its npz goes.
``restore`` rebuilds into the template pytree (shape/dtype checked leaf by
leaf).
"""
from __future__ import annotations

import json
import os
import re
import tempfile
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

_STEP_RE = re.compile(r"step_(\d+)\.npz$")


def _flatten(tree: Any):
    leaves = jax.tree_util.tree_leaves_with_path(tree)
    return {jax.tree_util.keystr(path): np.asarray(leaf)
            for path, leaf in leaves}


def save(directory: str | Path, step: int, tree: Any,
         keep: Optional[int] = 3) -> Path:
    """Write step_<k>.npz (+ manifest); prune to the newest `keep`."""
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    flat = _flatten(tree)
    path = directory / f"step_{step}.npz"
    manifest_path = directory / f"step_{step}.json"
    manifest = {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                for k, v in flat.items()}

    fd, tmp = tempfile.mkstemp(dir=directory, prefix=f"step_{step}.",
                               suffix=".tmp.npz")
    with os.fdopen(fd, "wb") as f:
        np.savez(f, **flat)
    os.replace(tmp, path)
    fd, tmp = tempfile.mkstemp(dir=directory, prefix=f"step_{step}.",
                               suffix=".tmp.json")
    with os.fdopen(fd, "w") as f:
        json.dump(manifest, f)
    os.replace(tmp, manifest_path)

    if keep is not None:
        # prune only *complete* steps (both files), never the one just
        # written; manifest goes first so the step vanishes from listings
        # before its data does (a racing restore either already resolved
        # its npz path or no longer sees the step)
        steps = sorted(all_steps(directory))
        for old in steps[:-keep]:
            if old == step:
                continue
            (directory / f"step_{old}.json").unlink(missing_ok=True)
            (directory / f"step_{old}.npz").unlink(missing_ok=True)
    return path


def all_steps(directory: str | Path):
    """Steps with BOTH the npz and its manifest (complete checkpoints)."""
    directory = Path(directory)
    if not directory.exists():
        return []
    return [int(m.group(1)) for p in directory.iterdir()
            if (m := _STEP_RE.search(p.name))
            and (directory / f"step_{m.group(1)}.json").exists()]


def latest_step(directory: str | Path) -> Optional[int]:
    steps = all_steps(directory)
    return max(steps) if steps else None


def restore(directory: str | Path, template: Any,
            step: Optional[int] = None) -> tuple[Any, int]:
    """Rebuild `template`'s pytree from the newest (or given) checkpoint."""
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    data = np.load(directory / f"step_{step}.npz")
    leaves_with_path = jax.tree_util.tree_leaves_with_path(template)
    treedef = jax.tree_util.tree_structure(template)
    out = []
    for path, leaf in leaves_with_path:
        key = jax.tree_util.keystr(path)
        if key not in data:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = data[key]
        want_shape = tuple(getattr(leaf, "shape", np.shape(leaf)))
        if tuple(arr.shape) != want_shape:
            raise ValueError(
                f"leaf {key}: checkpoint shape {arr.shape} != {want_shape}")
        want_dtype = getattr(leaf, "dtype", None)
        out.append(arr.astype(want_dtype) if want_dtype else arr)
    return jax.tree_util.tree_unflatten(treedef, out), step
