"""Synthetic-but-learnable LM token pipeline with per-worker sharding.

Offline container: no external corpora. The stream is a noisy affine
recurrence over the vocabulary,

    t_{i+1} = (a * t_i + b) mod V        with prob 1 - eps
              uniform(V)                 otherwise,

which a causal LM can actually learn (loss falls toward the entropy of the
noise floor), so the end-to-end examples and the ~100M-model training driver
produce meaningful curves. Batches are deterministic in (seed, step, worker):
every worker of the decentralized run draws a disjoint shard, which is what
the consensus objective (P2) needs — distinct local f_n with a common
optimum.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticLMConfig:
    vocab_size: int
    seq_len: int
    mult: int = 31
    add: int = 17
    noise: float = 0.1
    seed: int = 0


class SyntheticLM:
    """Deterministic synthetic token stream."""

    def __init__(self, cfg: SyntheticLMConfig):
        self.cfg = cfg
        v = cfg.vocab_size
        assert np.gcd(cfg.mult, v) == 1 or v % cfg.mult, \
            "mult should not collapse the vocabulary"

    def _seq(self, rng: np.random.Generator, n: int) -> np.ndarray:
        c = self.cfg
        t = np.empty(n + 1, dtype=np.int64)
        t[0] = rng.integers(0, c.vocab_size)
        for i in range(n):
            if rng.uniform() < c.noise:
                t[i + 1] = rng.integers(0, c.vocab_size)
            else:
                t[i + 1] = (t[i] * c.mult + c.add) % c.vocab_size
        return t

    def batch(self, step: int, batch_size: int,
              worker: int = 0) -> Dict[str, np.ndarray]:
        """(batch, seq) tokens + next-token labels, deterministic in
        (seed, step, worker)."""
        c = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([c.seed, worker, step]))
        toks = np.empty((batch_size, c.seq_len + 1), dtype=np.int32)
        for b in range(batch_size):
            toks[b] = self._seq(rng, c.seq_len)
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}

    def worker_batch(self, step: int, n_workers: int,
                     per_worker: int) -> Dict[str, np.ndarray]:
        """Stacked per-worker batches: leading axis = worker."""
        parts = [self.batch(step, per_worker, worker=w)
                 for w in range(n_workers)]
        return {k: np.stack([p[k] for p in parts]) for k in parts[0]}


def model_batch(cfg, data: Dict[str, np.ndarray], *,
                key: Optional[jax.Array] = None) -> Dict[str, jax.Array]:
    """Attach the modality-stub inputs an architecture needs.

    [vlm]: random patch embeddings; [audio]: random frame embeddings — the
    carve-out stub inputs (the backbone is real, the frontend is not).
    """
    batch = {k: jnp.asarray(v) for k, v in data.items()}
    lead = batch["tokens"].shape[:-1]
    key = key if key is not None else jax.random.PRNGKey(0)
    if cfg.mrope_sections is not None:
        s = batch["tokens"].shape[-1]
        pos = jnp.broadcast_to(jnp.arange(s)[None, :, None],
                               lead + (s, 3)).astype(jnp.int32)
        batch["positions"] = pos.reshape(lead + (s, 3))
    if cfg.vision_tokens:
        batch["vision_embeds"] = 0.02 * jax.random.normal(
            key, lead + (cfg.vision_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.is_encoder_decoder:
        batch["frames"] = 0.02 * jax.random.normal(
            key, lead + (cfg.source_positions, cfg.d_model), jnp.bfloat16)
    return batch
