"""Datasets for the paper's experiments (Table 1) + uniform partitioner.

* synth-linear / synth-logistic: synthetic sets in the style of Chen et al.
  (2018) ("LAG"): d=50, 1200 instances. Features drawn N(0, I) with a mild
  condition-number spread; linear targets use a fixed ground-truth theta with
  Gaussian noise; logistic labels are sampled from the true logit.
* Body Fat (d=14, 252 rows) and Derm (d=34, 358 rows): the UCI sets used in
  the paper are not redistributable offline, so we synthesize statistically
  matched surrogates (same d, same n, standardized features, realistic
  column correlations) behind the same loader API. This keeps the benchmark
  shapes and conditioning faithful; swap in the real CSVs via `path=` when
  available.

Samples are distributed uniformly across N workers (Sec. 7: "the number of
samples are uniformly distributed across the N workers").
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class RegressionData:
    x: np.ndarray          # (n_samples, d)
    y: np.ndarray          # (n_samples,)
    task: str              # "linear" | "logistic"
    name: str

    @property
    def dim(self) -> int:
        return self.x.shape[1]


def _feature_matrix(rng: np.random.Generator, n: int, d: int,
                    cond: float = 10.0) -> np.ndarray:
    """Gaussian features with eigenvalue spread (condition number ~cond)."""
    base = rng.standard_normal((n, d))
    scales = np.geomspace(1.0, 1.0 / cond, d)
    return (base * scales[None, :]).astype(np.float32)


def synth_linear(n: int = 1200, d: int = 50, noise: float = 0.1,
                 seed: int = 0) -> RegressionData:
    rng = np.random.default_rng(seed)
    x = _feature_matrix(rng, n, d)
    theta_true = rng.standard_normal(d).astype(np.float32)
    y = x @ theta_true + noise * rng.standard_normal(n).astype(np.float32)
    return RegressionData(x=x, y=y.astype(np.float32), task="linear",
                          name="synth-linear")


def synth_logistic(n: int = 1200, d: int = 50, seed: int = 0) -> RegressionData:
    rng = np.random.default_rng(seed)
    x = _feature_matrix(rng, n, d)
    theta_true = rng.standard_normal(d).astype(np.float32)
    logits = x @ theta_true
    probs = 1.0 / (1.0 + np.exp(-logits))
    y = np.where(rng.uniform(size=n) < probs, 1.0, -1.0)
    return RegressionData(x=x, y=y.astype(np.float32), task="logistic",
                          name="synth-logistic")


def body_fat(path: Optional[str] = None, seed: int = 1) -> RegressionData:
    """Body Fat (UCI): 252 x 14, linear regression target = body fat %."""
    if path is not None:
        raw = np.loadtxt(path, delimiter=",", skiprows=1)
        return RegressionData(x=raw[:, 1:].astype(np.float32),
                              y=raw[:, 0].astype(np.float32),
                              task="linear", name="bodyfat")
    rng = np.random.default_rng(seed)
    n, d = 252, 14
    # correlated anthropometric-style columns
    corr_root = rng.uniform(0.3, 1.0, size=(d, d)) * rng.choice(
        [0.0, 1.0], p=[0.6, 0.4], size=(d, d))
    np.fill_diagonal(corr_root, 1.0)
    x = rng.standard_normal((n, d)) @ (corr_root / np.sqrt(d))
    x = ((x - x.mean(0)) / (x.std(0) + 1e-9)).astype(np.float32)
    w = rng.standard_normal(d).astype(np.float32)
    y = x @ w + 0.3 * rng.standard_normal(n).astype(np.float32)
    return RegressionData(x=x, y=y.astype(np.float32), task="linear",
                          name="bodyfat-surrogate")


def derm(path: Optional[str] = None, seed: int = 2) -> RegressionData:
    """Dermatology (UCI): 358 x 34, binarized diagnosis, logistic task."""
    if path is not None:
        raw = np.loadtxt(path, delimiter=",")
        x = raw[:, :-1].astype(np.float32)
        y = np.where(raw[:, -1] > 1, -1.0, 1.0).astype(np.float32)
        return RegressionData(x=x, y=y, task="logistic", name="derm")
    rng = np.random.default_rng(seed)
    n, d = 358, 34
    x = rng.integers(0, 4, size=(n, d)).astype(np.float32)  # ordinal scores
    x = (x - x.mean(0)) / (x.std(0) + 1e-9)
    w = rng.standard_normal(d).astype(np.float32)
    logits = x @ w
    y = np.where(rng.uniform(size=n) < 1 / (1 + np.exp(-logits)), 1.0, -1.0)
    return RegressionData(x=x, y=y.astype(np.float32), task="logistic",
                          name="derm-surrogate")


def partition_uniform(data: RegressionData, n_workers: int,
                      seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """Shuffle and split rows uniformly across workers.

    Returns x (N, s, d), y (N, s) with s = floor(n / N) (tail dropped, as a
    uniform per-worker sample count is required by the batched solvers).
    """
    rng = np.random.default_rng(seed)
    order = rng.permutation(data.x.shape[0])
    s = data.x.shape[0] // n_workers
    idx = order[: s * n_workers].reshape(n_workers, s)
    return data.x[idx], data.y[idx]


def partition_dirichlet(data: RegressionData, n_workers: int,
                        alpha: float = 0.3, seed: int = 0,
                        n_bins: int = 10) -> Tuple[np.ndarray, np.ndarray]:
    """Non-IID split: each worker's local distribution is skewed by a
    Dirichlet(alpha) draw over target bins (the standard federated-learning
    heterogeneity knob; alpha -> inf recovers the IID split, alpha -> 0
    gives one-bin workers).

    Rows are bucketed by target value — the class label for logistic tasks,
    y-quantiles for regression — and worker n samples its rows with
    probability proportional to its own Dirichlet weight over the buckets.
    Unlike the usual proportion-split, every worker still gets exactly
    ``s = floor(n / N)`` rows (the batched solvers require a uniform
    per-worker sample count), so the skew lives entirely in *which* rows a
    worker sees, not how many. Sampling is with replacement within a
    worker's preferred bins when a bin runs dry — at small alpha several
    workers may all want the same rare bin.

    Returns x (N, s, d), y (N, s), same shapes as :func:`partition_uniform`.
    """
    assert alpha > 0.0
    rng = np.random.default_rng(seed)
    n = data.x.shape[0]
    s = n // n_workers
    if data.task == "logistic":
        labels = np.unique(data.y)
        bin_ids = np.searchsorted(labels, data.y)
        k = len(labels)
    else:
        k = min(n_bins, n)
        # quantile edges over y; searchsorted of interior edges -> 0..k-1
        edges = np.quantile(data.y, np.linspace(0, 1, k + 1)[1:-1])
        bin_ids = np.searchsorted(edges, data.y)
        k = int(bin_ids.max()) + 1  # degenerate y collapses bins
    weights = rng.dirichlet(np.full(k, alpha), size=n_workers)  # (N, k)
    idx = np.empty((n_workers, s), dtype=np.int64)
    for w in range(n_workers):
        probs = weights[w][bin_ids]
        probs = probs / probs.sum()
        idx[w] = rng.choice(n, size=s, replace=False, p=probs) \
            if (probs > 0).sum() >= s else rng.choice(n, size=s, p=probs)
    return data.x[idx], data.y[idx]


DATASETS = {
    "synth-linear": synth_linear,
    "synth-logistic": synth_logistic,
    "bodyfat": body_fat,
    "derm": derm,
}
