"""whisper-small [audio]: encoder-decoder; conv/mel frontend stubbed.

12 encoder + 12 decoder layers, d_model=768, 12 heads (MHA), d_ff=3072,
vocab=51865, 1500 encoder frames (30 s of audio after the conv stack, which
is stubbed — ``input_specs()`` provides post-conv frame embeddings).
[arXiv:2212.04356]

Decode shapes exercise the decoder with a self-attention KV cache plus
precomputed cross-attention KVs. ``long_500k`` is skipped (Whisper's decoder
is bounded at 448 learned positions; see DESIGN.md §Arch-applicability).
"""
from repro.configs.base import ModelConfig, register


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-small", arch_type="audio",
        num_layers=12, d_model=768, num_heads=12, num_kv_heads=12,
        d_ff=3072, vocab_size=51865, block_unit=("attn",),
        encoder_layers=12, source_positions=1500,
        pos_embedding="sinusoidal", tie_embeddings=True,
        source="arXiv:2212.04356",
        long_context="skip",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-smoke", arch_type="audio",
        num_layers=2, d_model=256, num_heads=4, num_kv_heads=4,
        d_ff=512, vocab_size=512, block_unit=("attn",),
        encoder_layers=2, source_positions=64,
        pos_embedding="sinusoidal",
        source="arXiv:2212.04356", long_context="skip",
    )


register("whisper-small", config, smoke_config)
