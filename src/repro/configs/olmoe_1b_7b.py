"""olmoe-1b-7b [moe]: 64 experts, top-8 routing, 1B active / 7B total.

16 layers, d_model=2048, 16 heads (GQA kv=16), expert d_ff=1024,
vocab=50304. [arXiv:2409.02060]
"""
from repro.configs.base import ModelConfig, register


def config() -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b", arch_type="moe",
        num_layers=16, d_model=2048, num_heads=16, num_kv_heads=16,
        d_ff=1024, vocab_size=50304, block_unit=("moe",),
        num_experts=64, experts_per_token=8,
        source="arXiv:2409.02060",
        long_context="swa_variant", long_context_window=4096,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="olmoe-smoke", arch_type="moe",
        num_layers=2, d_model=256, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=512, block_unit=("moe",),
        num_experts=4, experts_per_token=2,
        source="arXiv:2409.02060",
    )


register("olmoe-1b-7b", config, smoke_config)
