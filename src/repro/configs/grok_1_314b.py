"""grok-1-314b [moe]: 8 experts, top-2 routing.

64 layers, d_model=6144, 48 heads (GQA kv=8), expert d_ff=32768,
vocab=131072. [hf:xai-org/grok-1]
"""
from repro.configs.base import ModelConfig, register


def config() -> ModelConfig:
    return ModelConfig(
        name="grok-1-314b", arch_type="moe",
        num_layers=64, d_model=6144, num_heads=48, num_kv_heads=8,
        d_ff=32768, vocab_size=131072, block_unit=("moe",),
        num_experts=8, experts_per_token=2,
        source="hf:xai-org/grok-1",
        long_context="swa_variant", long_context_window=4096,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="grok-smoke", arch_type="moe",
        num_layers=2, d_model=256, num_heads=4, num_kv_heads=2,
        d_ff=512, vocab_size=512, block_unit=("moe",),
        num_experts=4, experts_per_token=2,
        source="hf:xai-org/grok-1",
    )


register("grok-1-314b", config, smoke_config)
