"""mistral-large-123b [dense].

88 layers, d_model=12288, 96 heads (GQA kv=8), d_ff=28672, vocab=32768.
[hf:mistralai/Mistral-Large-Instruct-2407]
"""
from repro.configs.base import ModelConfig, register


def config() -> ModelConfig:
    return ModelConfig(
        name="mistral-large-123b", arch_type="dense",
        num_layers=88, d_model=12288, num_heads=96, num_kv_heads=8,
        d_ff=28672, vocab_size=32768, block_unit=("attn",),
        head_dim=128,
        source="hf:mistralai/Mistral-Large-Instruct-2407",
        long_context="swa_variant", long_context_window=4096,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mistral-large-smoke", arch_type="dense",
        num_layers=2, d_model=256, num_heads=8, num_kv_heads=2,
        d_ff=512, vocab_size=512, block_unit=("attn",), head_dim=32,
        source="hf:mistralai/Mistral-Large-Instruct-2407",
    )


register("mistral-large-123b", config, smoke_config)
