"""h2o-danube-1.8b [dense]: llama+mistral mix with sliding-window attention.

24 layers, d_model=2560, 32 heads (GQA kv=8), d_ff=6912, vocab=32000,
sliding window 4096. [arXiv:2401.16818]
"""
from repro.configs.base import ModelConfig, register


def config() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-1.8b", arch_type="dense",
        num_layers=24, d_model=2560, num_heads=32, num_kv_heads=8,
        d_ff=6912, vocab_size=32000, block_unit=("swa",),
        sliding_window=4096,
        source="arXiv:2401.16818",
        long_context="native",   # base config is already windowed
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-smoke", arch_type="dense",
        num_layers=2, d_model=256, num_heads=8, num_kv_heads=2,
        d_ff=512, vocab_size=512, block_unit=("swa",), sliding_window=64,
        source="arXiv:2401.16818", long_context="native",
    )


register("h2o-danube-1.8b", config, smoke_config)
