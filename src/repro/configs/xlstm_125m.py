"""xlstm-125m [ssm]: alternating sLSTM + mLSTM blocks.

12 layers, d_model=768, 4 heads, vocab=50304 (d_ff=0: the xLSTM blocks carry
their own internal up/down projections). [arXiv:2405.04517]
"""
from repro.configs.base import ModelConfig, register


def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-125m", arch_type="ssm",
        num_layers=12, d_model=768, num_heads=4, num_kv_heads=4,
        d_ff=0, vocab_size=50304, block_unit=("mlstm", "slstm"),
        lstm_heads=4,
        source="arXiv:2405.04517",
        long_context="native",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-smoke", arch_type="ssm",
        num_layers=2, d_model=256, num_heads=4, num_kv_heads=4,
        d_ff=0, vocab_size=512, block_unit=("mlstm", "slstm"),
        lstm_heads=4,
        source="arXiv:2405.04517", long_context="native",
    )


register("xlstm-125m", config, smoke_config)
