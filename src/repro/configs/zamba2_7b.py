"""zamba2-7b [hybrid]: Mamba2 backbone + shared-weight attention blocks.

81 layers, d_model=3584, 32 heads (kv=32, i.e. MHA in the shared block),
d_ff=14336 (shared block MLP), vocab=32000, ssm_state=64. Every 6th block is
the *shared* attention+MLP block (one weight set reused at every occurrence,
zamba2-style); the rest are Mamba2 blocks. [arXiv:2411.15242]
"""
from repro.configs.base import ModelConfig, register

_UNIT = ("mamba2",) * 5 + ("shared_attn",)


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b", arch_type="hybrid",
        num_layers=81, d_model=3584, num_heads=32, num_kv_heads=32,
        d_ff=14336, vocab_size=32000, block_unit=_UNIT,
        ssm_state=64, ssm_expand=2, ssm_head_dim=64,
        source="arXiv:2411.15242",
        long_context="native",   # Mamba2 dominates; shared attn gets a window
        long_context_window=4096,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-smoke", arch_type="hybrid",
        num_layers=2, d_model=256, num_heads=4, num_kv_heads=4,
        d_ff=512, vocab_size=512, block_unit=("mamba2", "shared_attn"),
        ssm_state=16, ssm_expand=2, ssm_head_dim=32,
        source="arXiv:2411.15242", long_context="native",
    )


register("zamba2-7b", config, smoke_config)
