"""Config system: model / shape / ADMM / run configuration + registry.

Every assigned architecture registers a `ModelConfig` (exact paper/model-card
hyperparameters) plus a reduced smoke variant (<=2 layers, d_model <= 512,
<= 4 experts) used by CPU tests. Input shapes are the four assigned
(seq_len, global_batch, kind) tuples.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

# ---------------------------------------------------------------------------
# Block kinds understood by the model builder (models/registry.py):
#   "attn"        full causal self-attention + MLP (pre-norm)
#   "swa"         sliding-window causal self-attention + MLP
#   "moe"         full attention + mixture-of-experts MLP
#   "swa_moe"     sliding-window attention + MoE MLP
#   "mamba2"      Mamba2 SSD block
#   "shared_attn" attention+MLP block whose weights are SHARED across all
#                 occurrences (zamba2-style)
#   "mlstm"       xLSTM matrix-memory block
#   "slstm"       xLSTM scalar-memory block
# Encoder-decoder archs additionally use encoder_layers of bidirectional
# "attn" blocks and decoder blocks with cross-attention.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                      # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    block_unit: Tuple[str, ...]         # repeating unit of block kinds
    head_dim: Optional[int] = None
    # attention
    sliding_window: Optional[int] = None
    rope_theta: float = 10000.0
    mrope_sections: Optional[Tuple[int, int, int]] = None  # qwen2-vl M-RoPE
    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    # SSM (mamba2)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    # xLSTM
    lstm_heads: int = 4
    # encoder-decoder (audio)
    encoder_layers: int = 0
    source_positions: int = 0           # encoder sequence length (stub frames)
    # vlm stub
    vision_tokens: int = 0              # patch embeddings provided per sample
    # misc
    pos_embedding: str = "rope"         # rope | sinusoidal
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    source: str = ""                    # provenance citation
    # long-context policy: "native" (sub-quadratic already), "swa_variant"
    # (run long_500k with sliding_window override), "skip"
    long_context: str = "swa_variant"
    long_context_window: int = 4096

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def block_kinds(self) -> Tuple[str, ...]:
        """Per-layer kinds: block_unit tiled/truncated to num_layers."""
        unit = self.block_unit
        reps = -(-self.num_layers // len(unit))
        return (unit * reps)[: self.num_layers]

    @property
    def is_encoder_decoder(self) -> bool:
        return self.encoder_layers > 0

    def with_overrides(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def for_long_context(self) -> "ModelConfig":
        if self.long_context == "swa_variant":
            return self.with_overrides(sliding_window=self.long_context_window)
        return self

    # ---- analytic parameter / FLOP counts (roofline §) -------------------
    def param_count(self) -> int:
        from repro.models import registry
        return registry.count_params(self)

    def active_param_count(self) -> int:
        from repro.models import registry
        return registry.count_params(self, active_only=True)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                           # "train" | "prefill" | "decode"


INPUT_SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}
_SMOKE_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register(name: str, full: Callable[[], ModelConfig],
             smoke: Callable[[], ModelConfig]) -> None:
    _REGISTRY[name] = full
    _SMOKE_REGISTRY[name] = smoke


def get_config(name: str) -> ModelConfig:
    _ensure_imported()
    return _REGISTRY[name]()


def get_smoke_config(name: str) -> ModelConfig:
    _ensure_imported()
    return _SMOKE_REGISTRY[name]()


def list_architectures():
    _ensure_imported()
    return sorted(_REGISTRY)


def _ensure_imported() -> None:
    # importing the package registers every config module
    from repro import configs as _  # noqa: F401
    import importlib
    for mod in ("zamba2_7b", "gemma3_4b", "tinyllama_1_1b", "xlstm_125m",
                "grok_1_314b", "mistral_large_123b", "qwen2_vl_7b",
                "h2o_danube_1_8b", "olmoe_1b_7b", "whisper_small"):
        importlib.import_module(f"repro.configs.{mod}")
