"""gemma3-4b [dense]: 5:1 local:global attention, 128k context.

34 layers, d_model=2560, 8 heads (GQA kv=4), d_ff=10240, vocab=262144.
Repeating unit: 5 sliding-window (1024) layers then 1 global layer.
[hf:google/gemma-3-1b-pt]
"""
from repro.configs.base import ModelConfig, register

_UNIT = ("swa",) * 5 + ("attn",)


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-4b", arch_type="dense",
        num_layers=34, d_model=2560, num_heads=8, num_kv_heads=4,
        d_ff=10240, vocab_size=262144, block_unit=_UNIT,
        head_dim=256, sliding_window=1024, rope_theta=1_000_000.0,
        source="hf:google/gemma-3-1b-pt",
        # global layers get the window override under long_500k
        long_context="swa_variant", long_context_window=4096,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-smoke", arch_type="dense",
        num_layers=2, d_model=256, num_heads=4, num_kv_heads=2,
        d_ff=512, vocab_size=512, block_unit=("swa", "attn"),
        head_dim=64, sliding_window=64,
        source="hf:google/gemma-3-1b-pt",
    )


register("gemma3-4b", config, smoke_config)
