"""tinyllama-1.1b [dense]: llama2-architecture small model.

22 layers, d_model=2048, 32 heads (GQA kv=4), d_ff=5632, vocab=32000.
[arXiv:2401.02385]
"""
from repro.configs.base import ModelConfig, register


def config() -> ModelConfig:
    return ModelConfig(
        name="tinyllama-1.1b", arch_type="dense",
        num_layers=22, d_model=2048, num_heads=32, num_kv_heads=4,
        d_ff=5632, vocab_size=32000, block_unit=("attn",),
        source="arXiv:2401.02385",
        long_context="swa_variant", long_context_window=4096,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="tinyllama-smoke", arch_type="dense",
        num_layers=2, d_model=256, num_heads=8, num_kv_heads=2,
        d_ff=512, vocab_size=512, block_unit=("attn",),
        source="arXiv:2401.02385",
    )


register("tinyllama-1.1b", config, smoke_config)
