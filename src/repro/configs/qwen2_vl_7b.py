"""qwen2-vl-7b [vlm]: M-RoPE decoder; vision encoder stubbed to patch embeds.

28 layers, d_model=3584, 28 heads (GQA kv=4), d_ff=18944, vocab=152064.
M-RoPE splits each head's rotary half-dim (64) into (temporal=16, height=24,
width=24) sections. The ViT/merger frontend is a stub: ``input_specs()``
provides pre-projected patch embeddings. [arXiv:2409.12191]
"""
from repro.configs.base import ModelConfig, register


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-7b", arch_type="vlm",
        num_layers=28, d_model=3584, num_heads=28, num_kv_heads=4,
        d_ff=18944, vocab_size=152064, block_unit=("attn",),
        head_dim=128, mrope_sections=(16, 24, 24), rope_theta=1_000_000.0,
        vision_tokens=256,
        source="arXiv:2409.12191",
        long_context="swa_variant", long_context_window=4096,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-smoke", arch_type="vlm",
        num_layers=2, d_model=256, num_heads=4, num_kv_heads=2,
        d_ff=512, vocab_size=512, block_unit=("attn",),
        head_dim=64, mrope_sections=(8, 12, 12), vision_tokens=16,
        source="arXiv:2409.12191",
    )


register("qwen2-vl-7b", config, smoke_config)
