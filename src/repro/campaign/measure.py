"""Shared timing discipline for every benchmark stage.

All six bench modules time through these helpers so the rules live in one
place: the warm-up (compile) call is always ``block_until_ready``'d before
the first timed repeat — otherwise async dispatch from warm-up overlaps
(and inflates) the first measurement — and wall times are best-of-N with a
block after every repeat.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Iterable, Tuple

import jax
import numpy as np


def time_run(fn: Callable[[], object], repeats: int = 5) -> float:
    """Best-of-``repeats`` wall seconds of ``fn()`` after a blocked warm-up."""
    jax.block_until_ready(fn())            # compile; block so async dispatch
    best = float("inf")                    # cannot leak into the first repeat
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best


def time_per_call(fn: Callable, *args, reps: int = 3) -> Tuple[float, object]:
    """Mean microseconds per ``fn(*args)`` call after a blocked warm-up,
    plus the last output (for parity checks)."""
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    out = None
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6, out


def interleaved_median(fns: Iterable[Callable[[], object]], *,
                       rounds: int = 7, iters: int = 1) -> Tuple[float, ...]:
    """Median-of-``rounds`` per-call wall seconds for several callables,
    timed in interleaved rounds (A B A B ...) rather than arm-by-arm.

    Best-of-N timed arm-by-arm is the wrong discipline for RATIO gates on
    a shared container: a background-load spike during one arm's window
    skews the ratio even when both arms are unaffected code (the
    ``fused_range_dispatch_leq_twopass`` flake — 1.07-1.29x on unchanged
    code). Interleaving puts every arm inside every load window, and the
    per-arm median over rounds rejects the spiky rounds instead of
    rewarding whichever arm got the single quietest one. Each fn is
    compiled/warmed with a blocked call before any timing starts.
    """
    fns = list(fns)
    for fn in fns:
        jax.block_until_ready(fn())
    times = [[] for _ in fns]
    for _ in range(rounds):
        for j, fn in enumerate(fns):
            out = None
            t0 = time.perf_counter()
            for _ in range(iters):
                out = fn()
            jax.block_until_ready(out)
            times[j].append((time.perf_counter() - t0) / iters)
    return tuple(float(np.median(t)) for t in times)


def percentiles(seconds: Iterable[float]) -> Dict[str, float]:
    """p50/p99 latency summary in milliseconds."""
    arr = np.asarray(list(seconds), np.float64) * 1e3
    return {"p50_ms": float(np.percentile(arr, 50)),
            "p99_ms": float(np.percentile(arr, 99)),
            "n": int(arr.size)}
