"""Campaign runner: topological execution with retry and crash-resume.

Each run gets a directory ``<state_root>/<campaign>/<run_key>/`` holding

* ``status.json`` — ``pending/running/done/failed`` plus attempt count,
* ``record.json`` — the emitted :class:`~repro.campaign.store.Record`
  (written atomically; its existence marks the run completed),
* ``ckpt/`` — optional in-flight NPZ checkpoints a stage function writes
  through :meth:`RunContext.checkpoint` (``checkpoint/npz.py``).

Error classification: a stage function raises :class:`TransientError` for
failures worth retrying (flaky I/O, busy devices) — the runner retries with
exponential backoff up to ``RetryPolicy.max_retries``. Anything else is
fatal: recorded, not retried. ``KeyboardInterrupt``/``SystemExit``
propagate so a kill stops the campaign mid-flight; on re-invocation with
``resume=True`` completed runs are detected via ``record.json`` and their
records re-merged (not re-executed), which makes a resumed campaign's
merged document byte-identical to an uninterrupted one.
"""
from __future__ import annotations

import dataclasses
import inspect
import json
import time
import traceback
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.campaign.spec import Campaign, RunSpec, Stage
from repro.campaign.store import Record, ResultStore, atomic_write_json
from repro.checkpoint import npz as _npz
from repro.obs import trace as obs_trace

DEFAULT_STATE_ROOT = "campaigns"


class TransientError(RuntimeError):
    """Retryable failure (bounded retry with backoff)."""


class FatalError(RuntimeError):
    """Non-retryable failure: recorded and surfaced, never retried."""


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    max_retries: int = 2          # retries after the first attempt
    backoff_s: float = 0.5
    backoff_mult: float = 2.0

    def delay(self, attempt: int) -> float:
        """Backoff before retry number ``attempt`` (1-based)."""
        return self.backoff_s * self.backoff_mult ** (attempt - 1)


class RunContext:
    """Handed to stage functions that accept a ``ctx`` argument."""

    def __init__(self, spec: RunSpec, run_dir: Path, store: ResultStore):
        self.spec = spec
        self.dir = Path(run_dir)
        self.store = store

    @property
    def ckpt_dir(self) -> Path:
        return self.dir / "ckpt"

    def checkpoint(self, step: int, tree: Any, keep: int = 2) -> Path:
        """Checkpoint in-flight state (any pytree) at ``step``."""
        return _npz.save(self.ckpt_dir, step, tree, keep=keep)

    def restore(self, template: Any) -> Optional[Tuple[Any, int]]:
        """Latest in-flight checkpoint as ``(tree, step)``, else None."""
        if _npz.latest_step(self.ckpt_dir) is None:
            return None
        return _npz.restore(self.ckpt_dir, template)


@dataclasses.dataclass
class RunResult:
    spec: RunSpec
    status: str                   # done | skipped | failed | blocked
    attempts: int = 0
    error: str = ""
    claims_failed: int = 0


@dataclasses.dataclass
class Summary:
    campaign: str
    results: List[RunResult]

    def count(self, status: str) -> int:
        return sum(r.status == status for r in self.results)

    @property
    def executed(self) -> int:
        return self.count("done")

    @property
    def skipped(self) -> int:
        return self.count("skipped")

    @property
    def failed(self) -> int:
        return self.count("failed") + self.count("blocked")

    @property
    def claims_failed(self) -> int:
        return sum(r.claims_failed for r in self.results)

    @property
    def exit_code(self) -> int:
        return 1 if (self.failed or self.claims_failed) else 0


class Runner:
    """Execute one campaign against a store and a state directory."""

    def __init__(self, campaign: Campaign,
                 store: Optional[ResultStore] = None,
                 state_root: str | Path = DEFAULT_STATE_ROOT,
                 retry: RetryPolicy = RetryPolicy(),
                 resume: bool = False,
                 only: Optional[str] = None,
                 sleep: Callable[[float], None] = time.sleep):
        self.campaign = campaign
        self.store = store if store is not None else ResultStore()
        self.state_root = Path(state_root)
        self.retry = retry
        self.resume = resume
        self.only = only
        self.sleep = sleep

    # ------------------------------------------------------------ layout --
    def run_dir(self, spec: RunSpec) -> Path:
        return self.state_root / self.campaign.name / spec.key

    def completed(self, spec: RunSpec) -> bool:
        return (self.run_dir(spec) / "record.json").exists()

    def _load_record(self, spec: RunSpec) -> Record:
        import json
        with open(self.run_dir(spec) / "record.json") as f:
            return Record.from_json(json.load(f))

    def _set_status(self, spec: RunSpec, status: str, attempts: int = 0,
                    error: str = "") -> None:
        atomic_write_json(self.run_dir(spec) / "status.json",
                          {"stage": spec.stage, "name": spec.display,
                           "key": spec.key, "status": status,
                           "attempts": attempts, "error": error})

    def _event(self, **fields: Any) -> None:
        """Append one structured event to ``<campaign>/events.jsonl`` — the
        machine-readable mirror of the ``run,...``/``claim,...`` stdout
        lines (whose format CI parses and which stays byte-identical).
        A single write() of a complete line keeps appends atomic."""
        path = self.state_root / self.campaign.name / "events.jsonl"
        path.parent.mkdir(parents=True, exist_ok=True)
        fields.setdefault("ts", time.time())
        with open(path, "a") as f:
            f.write(json.dumps(fields, sort_keys=True) + "\n")

    def _meta(self, spec: RunSpec) -> Dict[str, Any]:
        return {"campaign": self.campaign.name, "stage": spec.stage,
                "key": spec.key, "name": spec.display}

    # --------------------------------------------------------- execution --
    def _stage_plan(self) -> List[Tuple[Stage, bool]]:
        """Topologically-ordered ``(stage, resume_for_stage)`` pairs.

        With ``only``, the target stage plus its transitive deps are
        selected; dependency stages always run resume-style (their
        completed runs are skipped, incomplete ones executed) so the
        target sees satisfied dependencies without redundant re-execution.
        """
        order = self.campaign.topological()
        if self.only is None:
            return [(s, self.resume) for s in order]
        need = set(self.campaign.closure(self.only))
        return [(s, True if s.name != self.only else self.resume)
                for s in order if s.name in need]

    def run(self) -> Summary:
        results: List[RunResult] = []
        failed_stages: set = set()
        for st, stage_resume in self._stage_plan():
            blocked = [d for d in st.deps if d in failed_stages]
            if blocked:
                for spec in st.runs:
                    print(f"run,{st.name},{spec.key},{spec.display},blocked")
                    self._event(event="run", status="blocked",
                                **self._meta(spec))
                    results.append(RunResult(spec, "blocked",
                                             error=f"dependency failed: "
                                                   f"{blocked}"))
                failed_stages.add(st.name)
                continue
            stage_failed = False
            for spec in st.runs:
                res = self._run_one(spec, stage_resume)
                results.append(res)
                stage_failed |= res.status == "failed"
            if stage_failed:
                failed_stages.add(st.name)
        summary = Summary(self.campaign.name, results)
        print(f"# campaign {self.campaign.name}: "
              f"executed={summary.executed} skipped={summary.skipped} "
              f"failed={summary.failed} "
              f"claim_failures={summary.claims_failed}")
        self._event(event="summary", campaign=self.campaign.name,
                    executed=summary.executed, skipped=summary.skipped,
                    failed=summary.failed,
                    claim_failures=summary.claims_failed)
        return summary

    def _run_one(self, spec: RunSpec, resume: bool) -> RunResult:
        rdir = self.run_dir(spec)
        if resume and self.completed(spec):
            # re-merge the persisted record so the store document is
            # complete (and byte-identical) even if the previous process
            # died between the record write and the store merge
            record = self._load_record(spec)
            self.store.merge(record, meta=self._meta(spec))
            print(f"run,{spec.stage},{spec.key},{spec.display},skipped")
            self._event(event="run", status="skipped", **self._meta(spec))
            return RunResult(spec, "skipped")

        rdir.mkdir(parents=True, exist_ok=True)
        tr = obs_trace.tracer()
        tid = tr.track("campaign", f"{spec.stage}/{spec.display}") \
            if tr is not None else 0
        if tr is not None:
            tr.begin("run", "campaign", tid,
                     args={"stage": spec.stage, "key": spec.key,
                           "name": spec.display})
        try:
            return self._execute(spec, rdir, tr, tid)
        finally:
            if tr is not None:
                tr.end("campaign", tid)

    def _execute(self, spec: RunSpec, rdir: Path, tr, tid) -> RunResult:
        fn = spec.resolve()
        kwargs = dict(spec.config)
        if "ctx" in inspect.signature(fn).parameters:
            kwargs["ctx"] = RunContext(spec, rdir, self.store)

        attempts = 0
        while True:
            attempts += 1
            self._set_status(spec, "running", attempts)
            try:
                record = fn(**kwargs)
                break
            except TransientError as e:
                if attempts > self.retry.max_retries:
                    return self._fail(spec, attempts,
                                      f"transient (retries exhausted): {e}")
                delay = self.retry.delay(attempts)
                print(f"# run {spec.stage}/{spec.display}: transient "
                      f"failure (attempt {attempts}), retrying in "
                      f"{delay:.1f}s: {e}")
                self._event(event="retry", attempt=attempts, error=str(e),
                            **self._meta(spec))
                if tr is not None:
                    tr.instant("retry", "campaign", tid,
                               args={"attempt": attempts})
                self.sleep(delay)
            except (KeyboardInterrupt, SystemExit):
                raise                         # a kill stops the campaign
            except Exception as e:            # fatal: never retried
                traceback.print_exc()
                return self._fail(spec, attempts, f"fatal: {e}")

        if not isinstance(record, Record):
            return self._fail(spec, attempts,
                              f"stage fn returned {type(record).__name__}, "
                              f"expected campaign.store.Record")
        # persist, then merge FROM the persisted bytes: the fresh path and
        # the resumed path go through the identical JSON round-trip, so a
        # killed-and-resumed campaign reproduces the same document bytes
        atomic_write_json(rdir / "record.json", record.to_json())
        record = self._load_record(spec)
        self.store.merge(record, meta=self._meta(spec))
        self._set_status(spec, "done", attempts)
        n_bad = sum(not c.ok for c in record.claims)
        for c in record.claims:
            print(f"claim,{spec.stage},{c.name},{'PASS' if c.ok else 'FAIL'}")
            self._event(event="claim", claim=c.name, ok=bool(c.ok),
                        **self._meta(spec))
        print(f"run,{spec.stage},{spec.key},{spec.display},done")
        self._event(event="run", status="done", attempts=attempts,
                    **self._meta(spec))
        return RunResult(spec, "done", attempts, claims_failed=n_bad)

    def _fail(self, spec: RunSpec, attempts: int, error: str) -> RunResult:
        self._set_status(spec, "failed", attempts, error)
        print(f"run,{spec.stage},{spec.key},{spec.display},failed  # {error}")
        self._event(event="run", status="failed", attempts=attempts,
                    error=error, **self._meta(spec))
        return RunResult(spec, "failed", attempts, error)

    # ------------------------------------------------------------ listing --
    def describe(self) -> List[str]:
        """Human-readable plan with per-run completion status."""
        lines = [f"campaign {self.campaign.name}:"]
        for st in self.campaign.topological():
            deps = f" (deps: {', '.join(st.deps)})" if st.deps else ""
            lines.append(f"  stage {st.name} [{len(st.runs)} runs]{deps}")
            for spec in st.runs:
                mark = "done   " if self.completed(spec) else "pending"
                lines.append(f"    [{mark}] {spec.key}  {spec.display}")
        return lines
