"""Typed results store — the single owner of ``BENCH_engine.json`` writes.

Every run emits a :class:`Record`: a JSON section (placed at ``section``, a
key path into the document) plus :class:`Claim` objects (merged as plain
``{name: bool}`` under ``claims_path``, the format the CI gates read).
Merges are atomic — the whole document is rewritten through a temp file and
``os.replace`` (same discipline as ``checkpoint/npz.py``) — so a crash
mid-write can never corrupt the file and concurrent mergers can never
interleave partial dumps. Sections merge key-stably: re-merging an existing
section updates it in place, so a resumed campaign reproduces the same
document bytes as an uninterrupted one.

Because ``BENCH_engine.json`` is overwritten in place, it only ever holds
the *latest* measurement — the perf trajectory across campaign runs used
to be unrecoverable. Every merge therefore also appends the record (with a
wall-clock timestamp and the merging campaign/run identity) to an
append-only sibling ``BENCH_history.jsonl``: one JSON object per line,
written as a single ``write()`` of a fully-built line so concurrent
appenders cannot interleave partial records.
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile
from pathlib import Path
from typing import Any, Dict, Mapping, Optional, Tuple

import numpy as np

DEFAULT_PATH = "BENCH_engine.json"
HISTORY_NAME = "BENCH_history.jsonl"


@dataclasses.dataclass(frozen=True)
class Claim:
    """One CI-gateable boolean check, with optional provenance."""

    name: str
    ok: bool
    value: Any = None             # the measured quantity behind the bool
    gate: str = ""                # human-readable threshold, e.g. "< 1.1"

    def to_json(self) -> Dict[str, Any]:
        return {"name": self.name, "ok": bool(self.ok),
                "value": sanitize(self.value), "gate": self.gate}

    @staticmethod
    def from_json(d: Mapping[str, Any]) -> "Claim":
        return Claim(name=d["name"], ok=bool(d["ok"]),
                     value=d.get("value"), gate=d.get("gate", ""))


@dataclasses.dataclass(frozen=True)
class Record:
    """What one run produced: a section of metrics plus its claims."""

    section: Tuple[str, ...]              # key path for ``data``
    data: Mapping[str, Any]
    claims: Tuple[Claim, ...] = ()
    claims_path: Tuple[str, ...] = ("claims",)

    def to_json(self) -> Dict[str, Any]:
        return {"section": list(self.section),
                "data": sanitize(self.data),
                "claims": [c.to_json() for c in self.claims],
                "claims_path": list(self.claims_path)}

    @staticmethod
    def from_json(d: Mapping[str, Any]) -> "Record":
        return Record(section=tuple(d["section"]), data=d["data"],
                      claims=tuple(Claim.from_json(c) for c in d["claims"]),
                      claims_path=tuple(d["claims_path"]))


def sanitize(obj: Any) -> Any:
    """Recursively convert numpy scalars/arrays to plain JSON values."""
    if isinstance(obj, dict):
        return {str(k): sanitize(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [sanitize(v) for v in obj]
    if isinstance(obj, np.ndarray):
        return sanitize(obj.tolist())
    if isinstance(obj, (np.bool_, bool)):
        return bool(obj)
    if isinstance(obj, (np.integer, int)):
        return int(obj)
    if isinstance(obj, (np.floating, float)):
        return float(obj)
    return obj


def atomic_write_json(path: Path, obj: Any) -> None:
    """tmp + ``os.replace`` in the target directory (rename is atomic only
    within a filesystem)."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=path.parent, prefix=path.name + ".",
                               suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(obj, f, indent=2)
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


class ResultStore:
    """Atomic section merges into one JSON results document."""

    def __init__(self, path: str | Path = DEFAULT_PATH,
                 history_path: Optional[str | Path] = None):
        self.path = Path(path)
        self.history_path = Path(history_path) if history_path is not None \
            else self.path.parent / HISTORY_NAME

    def load(self) -> Dict[str, Any]:
        if not self.path.exists():
            return {}
        with open(self.path) as f:
            return json.load(f)

    def merge(self, record: Record,
              meta: Optional[Mapping[str, Any]] = None) -> None:
        """Place ``record.data`` at its section path and its claims (as
        ``{name: bool}``) under ``claims_path``, then rewrite atomically.
        The record is also appended to ``BENCH_history.jsonl`` with a
        timestamp plus ``meta`` (the campaign/stage/run identity the
        runner passes), preserving the trajectory the in-place document
        overwrites."""
        if not record.section:
            raise ValueError("record.section must name at least one key")
        doc = self.load()
        node = self._descend(doc, record.section[:-1])
        node[record.section[-1]] = sanitize(record.data)
        if record.claims:
            cnode = self._descend(doc, record.claims_path)
            for c in record.claims:
                cnode[c.name] = bool(c.ok)
        atomic_write_json(self.path, doc)
        self._append_history(record, meta)

    def _append_history(self, record: Record,
                        meta: Optional[Mapping[str, Any]]) -> None:
        import time
        entry = {"ts": time.time(), "meta": sanitize(dict(meta or {})),
                 **record.to_json()}
        line = json.dumps(entry, sort_keys=True) + "\n"
        self.history_path.parent.mkdir(parents=True, exist_ok=True)
        # one write() of a complete line on an O_APPEND handle: atomic
        # with respect to concurrent appenders
        with open(self.history_path, "a") as f:
            f.write(line)

    def history(self) -> list:
        """All BENCH_history.jsonl entries, oldest first (empty if none)."""
        if not self.history_path.exists():
            return []
        with open(self.history_path) as f:
            return [json.loads(ln) for ln in f if ln.strip()]

    @staticmethod
    def _descend(doc: Dict[str, Any], path: Tuple[str, ...]) -> Dict[str, Any]:
        node = doc
        for key in path:
            nxt = node.get(key)
            if not isinstance(nxt, dict):
                nxt = node[key] = {}
            node = nxt
        return node

    def section(self, path: Tuple[str, ...]) -> Optional[Any]:
        """Read one section (``None`` when absent) — for aggregation runs
        that compare against an earlier stage's merged results."""
        node: Any = self.load()
        for key in path:
            if not isinstance(node, dict) or key not in node:
                return None
            node = node[key]
        return node
