"""Campaign subsystem: declarative, resumable experiment DAGs.

A *campaign* is a named DAG of *stages*; a stage is an ordered list of
*runs*, each a pure function call described by a ``RunSpec`` (module path
plus a resolved, JSON-serializable config that deterministically hashes to
the run's key). The :mod:`~repro.campaign.runner` executes stages in
topological order with transient-vs-fatal retry and crash-resume; every
run emits a typed :class:`~repro.campaign.store.Record` whose sections and
claims the :class:`~repro.campaign.store.ResultStore` merges atomically
into ``BENCH_engine.json``. See DESIGN.md §Campaign.
"""
from repro.campaign.measure import percentiles, time_per_call, time_run
from repro.campaign.runner import (FatalError, RetryPolicy, RunContext,
                                   Runner, TransientError)
from repro.campaign.spec import (CAMPAIGNS, Campaign, RunSpec, Stage,
                                 get_campaign, register_campaign, run_key,
                                 stage, sweep)
from repro.campaign.store import Claim, Record, ResultStore

__all__ = [
    "CAMPAIGNS", "Campaign", "Claim", "FatalError", "Record", "ResultStore",
    "RetryPolicy", "RunContext", "Runner", "RunSpec", "Stage", "TransientError",
    "get_campaign", "percentiles", "register_campaign", "run_key", "stage",
    "sweep", "time_per_call", "time_run",
]
