"""Tiny stage functions exercising the runner's failure/resume semantics.

These exist so tests (and the CI resume smoke) can drive real campaigns
without the benchmark workloads: every behavior is controlled through the
run config — attempt counting lands in ``calls_dir`` files, transient and
fatal failures are triggered by counters and marker files, and a marker
can simulate a mid-campaign kill (``KeyboardInterrupt``). Record data is
deterministic (attempt counts are deliberately excluded) so the
byte-identity of resumed-vs-fresh documents is testable.
"""
from __future__ import annotations

from pathlib import Path
from typing import Optional, Sequence

import numpy as np

from repro.campaign.runner import FatalError, TransientError
from repro.campaign.store import Claim, Record


def _count_call(calls_dir: Optional[str], tag: str) -> int:
    if calls_dir is None:
        return 1
    path = Path(calls_dir) / f"{tag}.calls"
    path.parent.mkdir(parents=True, exist_ok=True)
    n = int(path.read_text()) + 1 if path.exists() else 1
    path.write_text(str(n))
    return n


def emit(tag: str, value: float = 0.0,
         section: Sequence[str] = ("selftest",),
         calls_dir: Optional[str] = None,
         transient_failures: int = 0,
         fatal_marker: Optional[str] = None,
         die_marker: Optional[str] = None,
         ctx=None) -> Record:
    """Emit one deterministic record, optionally failing first.

    * ``transient_failures=k``: the first k calls raise TransientError;
    * ``fatal_marker``: raise FatalError while that file exists;
    * ``die_marker``: raise KeyboardInterrupt while that file exists (a
      simulated SIGINT/SIGTERM mid-campaign).
    """
    calls = _count_call(calls_dir, tag)
    if die_marker is not None and Path(die_marker).exists():
        raise KeyboardInterrupt(f"simulated kill during {tag}")
    if fatal_marker is not None and Path(fatal_marker).exists():
        raise FatalError(f"fatal marker present for {tag}")
    if calls <= transient_failures:
        raise TransientError(f"{tag}: transient failure {calls}")
    return Record(section=tuple(section) + (tag,),
                  data={"tag": tag, "value": value},
                  claims=(Claim(f"{tag}_finite", bool(np.isfinite(value)),
                                value=value, gate="finite"),),
                  claims_path=tuple(section) + ("claims",))


def accumulate(tag: str, steps: int = 8,
               section: Sequence[str] = ("selftest",),
               die_marker: Optional[str] = None,
               die_at_step: int = -1,
               ctx=None) -> Record:
    """A multi-step run checkpointing in-flight state through ``ctx``.

    Accumulates ``sum(range(steps))`` one step at a time, checkpointing
    after every step; when ``die_marker`` exists the run is killed at
    ``die_at_step``. A resumed invocation restores the NPZ checkpoint and
    finishes from there — ``resumed_from`` records where it picked up.
    """
    template = {"acc": np.zeros((), np.float64)}
    start, acc = 0, 0.0
    if ctx is not None:
        restored = ctx.restore(template)
        if restored is not None:
            tree, start = restored
            acc = float(tree["acc"])
    for step in range(start, steps):
        if (die_marker is not None and step == die_at_step
                and Path(die_marker).exists()):
            raise KeyboardInterrupt(f"simulated kill at step {step}")
        acc += float(step)
        if ctx is not None:
            ctx.checkpoint(step + 1, {"acc": np.float64(acc)})
    return Record(section=tuple(section) + (tag,),
                  data={"tag": tag, "acc": acc, "resumed_from": start},
                  claims=(Claim(f"{tag}_sum_ok",
                                acc == sum(range(steps)),
                                value=acc, gate=f"== {sum(range(steps))}"),),
                  claims_path=tuple(section) + ("claims",))
