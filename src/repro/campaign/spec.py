"""Declarative campaign specs (DESIGN.md §Campaign).

Specs are plain Python — dataclasses over dicts, no YAML dependency. A
campaign is named stages of runs with explicit inter-stage dependencies; a
run is a ``RunSpec``: a lazily-imported function (``"module.path:func"``)
plus the resolved config it is called with. The run's identity is the
SHA-256 hash of the canonical JSON of ``(stage, fn, config)`` — two specs
with the same resolved config share a key (and therefore a results
directory), any config change yields a new key, and key computation never
imports the target module.

``sweep(**axes)`` is the grid expander: the Cartesian product of the axes
in the given order, each point a plain config dict ready to become one
``RunSpec``.
"""
from __future__ import annotations

import dataclasses
import hashlib
import importlib
import itertools
import json
from typing import Any, Callable, Dict, Iterable, List, Mapping, Optional, \
    Sequence, Tuple


def sweep(**axes: Iterable) -> List[Dict[str, Any]]:
    """Expand named axes into the full grid, one config dict per point.

    >>> sweep(groups=["model", "leaf"], censor_mode=["global"])
    [{'groups': 'model', 'censor_mode': 'global'},
     {'groups': 'leaf', 'censor_mode': 'global'}]
    """
    expanded = {name: list(vals) for name, vals in axes.items()}
    return [dict(zip(expanded, point))
            for point in itertools.product(*expanded.values())]


def canonical_json(obj: Any) -> str:
    """Deterministic JSON: sorted keys, no whitespace — the hash input."""
    return json.dumps(obj, sort_keys=True, separators=(",", ":"))


def run_key(stage_name: str, fn: str, config: Mapping[str, Any]) -> str:
    """Deterministic run identity from the resolved config (12 hex chars)."""
    payload = canonical_json(
        {"stage": stage_name, "fn": fn, "config": dict(config)})
    return hashlib.sha256(payload.encode()).hexdigest()[:12]


@dataclasses.dataclass(frozen=True)
class RunSpec:
    """One run: a function reference plus its fully-resolved config."""

    stage: str
    fn: str                       # "module.path:function", imported lazily
    config: Mapping[str, Any] = dataclasses.field(default_factory=dict)
    name: str = ""                # display name; derived when empty

    def __post_init__(self):
        if ":" not in self.fn:
            raise ValueError(f"fn must be 'module:function', got {self.fn!r}")
        try:
            canonical_json(dict(self.config))
        except TypeError as e:
            raise TypeError(
                f"run config for {self.fn} must be JSON-serializable "
                f"(it is hashed into the run key): {e}") from e

    @property
    def key(self) -> str:
        return run_key(self.stage, self.fn, self.config)

    @property
    def display(self) -> str:
        if self.name:
            return self.name
        if self.config:
            return " ".join(f"{k}={v}" for k, v in self.config.items())
        return self.fn.split(":")[-1]

    def resolve(self) -> Callable:
        module, func = self.fn.split(":", 1)
        return getattr(importlib.import_module(module), func)


@dataclasses.dataclass(frozen=True)
class Stage:
    """An ordered list of runs plus the stages that must complete first."""

    name: str
    runs: Tuple[RunSpec, ...]
    deps: Tuple[str, ...] = ()


def stage(name: str, fn: str,
          configs: Optional[Sequence[Mapping[str, Any]]] = None,
          deps: Sequence[str] = (),
          names: Optional[Sequence[str]] = None) -> Stage:
    """Build a Stage with one ``RunSpec`` per config (default: one run)."""
    configs = list(configs) if configs is not None else [{}]
    names = list(names) if names is not None else [""] * len(configs)
    if len(names) != len(configs):
        raise ValueError(f"stage {name}: {len(names)} names for "
                         f"{len(configs)} configs")
    runs = tuple(RunSpec(stage=name, fn=fn, config=dict(c), name=n)
                 for c, n in zip(configs, names))
    return Stage(name=name, runs=runs, deps=tuple(deps))


@dataclasses.dataclass(frozen=True)
class Campaign:
    """A named DAG of stages. ``validate()`` runs at registration."""

    name: str
    stages: Tuple[Stage, ...]

    def stage(self, name: str) -> Stage:
        for s in self.stages:
            if s.name == name:
                return s
        raise KeyError(f"campaign {self.name} has no stage {name!r} "
                       f"(stages: {[s.name for s in self.stages]})")

    def validate(self) -> None:
        names = [s.name for s in self.stages]
        if len(set(names)) != len(names):
            raise ValueError(f"campaign {self.name}: duplicate stage names")
        for s in self.stages:
            for d in s.deps:
                if d not in names:
                    raise ValueError(f"campaign {self.name}: stage {s.name} "
                                     f"depends on unknown stage {d!r}")
        self.topological()                     # raises on cycles
        keys = [r.key for s in self.stages for r in s.runs]
        if len(set(keys)) != len(keys):
            raise ValueError(f"campaign {self.name}: duplicate run keys "
                             f"(two runs share stage+fn+config)")

    def topological(self) -> Tuple[Stage, ...]:
        """Stages in dependency order, stable w.r.t. declaration order."""
        done: List[Stage] = []
        placed: set = set()
        remaining = list(self.stages)
        while remaining:
            ready = [s for s in remaining
                     if all(d in placed for d in s.deps)]
            if not ready:
                raise ValueError(f"campaign {self.name}: dependency cycle "
                                 f"among {[s.name for s in remaining]}")
            for s in ready:
                done.append(s)
                placed.add(s.name)
                remaining.remove(s)
        return tuple(done)

    def closure(self, stage_name: str) -> Tuple[str, ...]:
        """``stage_name`` plus its transitive dependencies."""
        need = {stage_name}
        frontier = [stage_name]
        while frontier:
            for d in self.stage(frontier.pop()).deps:
                if d not in need:
                    need.add(d)
                    frontier.append(d)
        return tuple(s.name for s in self.stages if s.name in need)

    def subset(self, stage_names: Sequence[str]) -> "Campaign":
        """A campaign restricted to ``stage_names`` (deps must survive)."""
        keep = set(stage_names)
        sub = Campaign(name=self.name,
                       stages=tuple(s for s in self.stages
                                    if s.name in keep))
        sub.validate()
        return sub


CAMPAIGNS: Dict[str, Campaign] = {}


def register_campaign(campaign: Campaign) -> Campaign:
    campaign.validate()
    CAMPAIGNS[campaign.name] = campaign
    return campaign


def get_campaign(name: str) -> Campaign:
    if name not in CAMPAIGNS:
        raise KeyError(f"unknown campaign {name!r} "
                       f"(registered: {sorted(CAMPAIGNS)})")
    return CAMPAIGNS[name]
